"""PredictionService: request batching over the fused predict kernel.

A scheduler planning T tasks on N nodes issues T x N runtime queries; the
old path dispatched one predict_blr per query (a JAX dispatch per scalar —
thousands of host round-trips per scheduling pass).  The service stacks
every task posterior into contiguous arrays once (re-stacked lazily when
the online predictor's version bumps), gathers per-query leaves, and
evaluates means/stds for the whole batch in ONE call to
`kernels.ops.bayes_predict` (Pallas on TPU, vmapped reference elsewhere).
Extrapolation factors are deterministic scalar rescalings applied outside
the kernel (cached per (task, node)).

Works with any predictor exposing `task_names() / export_posterior(task) /
factor(task, bench)` — both LotaruPredictor and OnlinePredictor do.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import bayes
from repro.core.extrapolation import MachineBench
from repro.core.traces import PredictionRow
from repro.kernels import ops
from repro.online.events import PredictionQuery, resolve_bench

_LEAVES = ("mu", "sigma", "beta_prec", "x_mu", "x_sd", "y_mu", "y_sd")


class PredictionService:
    def __init__(self, predictor,
                 benches: Optional[Mapping[str, MachineBench]] = None,
                 z: float = 1.96, impl: str = "auto"):
        self.predictor = predictor
        self.benches = dict(benches or {})
        self.z = z
        self.impl = impl
        self._stack: Dict[str, np.ndarray] = {}
        self._index: Dict[str, int] = {}
        self._factor_cache: Dict[Tuple[str, str], float] = {}
        self._version = -1
        self.refresh()

    # ---- posterior stacking -------------------------------------------------
    def _current_version(self) -> int:
        return getattr(self.predictor, "version", 0)

    def refresh(self) -> None:
        """Restack posterior leaves (cheap: one small array per abstract
        task — T is the number of task *models*, not DAG vertices).  The
        factor cache survives: it holds only the static extrapolation
        factors; streaming node corrections are applied at query time."""
        tasks = list(self.predictor.task_names())
        posts = [self.predictor.export_posterior(t) for t in tasks]
        self._index = {t: i for i, t in enumerate(tasks)}
        # float64 stack: the CPU predict path must reproduce the scalar
        # path exactly, including full-precision medians from
        # constant_posterior; the TPU kernel path downcasts at its boundary
        self._stack = {k: np.stack([np.asarray(p[k], np.float64)
                                    for p in posts]) for k in _LEAVES}
        self._version = self._current_version()

    def _maybe_refresh(self) -> None:
        if self._version != self._current_version():
            self.refresh()

    def _bench(self, node: Optional[str]) -> Optional[MachineBench]:
        return resolve_bench(self.benches, node)

    def _base_factor(self, task: str, node: Optional[str]) -> float:
        """static Section 4.6 factor, cacheable forever (corrections from
        streaming observations are composed on top per query)."""
        if node is None:
            return 1.0                 # local machine (events.py contract)
        key = (task, node)
        f = self._factor_cache.get(key)
        if f is None:
            bench = self._bench(node)
            if bench is None:
                raise KeyError(f"no benchmark registered for node {node!r}; "
                               f"known: {sorted(self.benches)}")
            base = getattr(self.predictor, "base", self.predictor)
            f = base.factor(task, bench)
            self._factor_cache[key] = f
        return f

    # ---- batched prediction -------------------------------------------------
    def predict_batch(self, queries: Sequence[PredictionQuery]
                      ) -> np.ndarray:
        """-> (Q, 3) array of [mean, lower, upper] seconds."""
        if not queries:
            return np.zeros((0, 3), np.float32)
        self._maybe_refresh()
        idx = np.asarray([self._index[q.task] for q in queries], np.int64)
        x = np.asarray([q.input_gb for q in queries])
        if self.impl in ("pallas", "interpret") or (
                self.impl == "auto" and ops._on_tpu()):
            post = {k: jnp.asarray(v[idx]) for k, v in self._stack.items()}
            mean, std = ops.bayes_predict(jnp.asarray(x, jnp.float32), post,
                                          impl=self.impl)
            mean = np.asarray(mean, np.float64)
            std = np.asarray(std, np.float64)
        else:
            # off-TPU: the same float64 elementwise math as the scalar path,
            # vectorized — bit-identical to per-query predict_blr_np
            post = {k: v[idx] for k, v in self._stack.items()}
            mean, std = bayes.predict_blr_np(post, x)
        corr_fn = getattr(self.predictor, "node_correction", None)
        corr = ({n: corr_fn(n) for n in {q.node for q in queries}}
                if corr_fn else {})
        f = np.asarray([self._base_factor(q.task, q.node)
                        * corr.get(q.node, 1.0) for q in queries])
        mean = np.maximum(mean, 1e-3) * f
        std = std * f
        lower = np.maximum(mean - self.z * std, 0.0)
        upper = mean + self.z * std
        return np.stack([mean, lower, upper], axis=1)

    def predict_rows(self, dag_tasks, targets: Sequence[MachineBench],
                     workflow: str) -> List[PredictionRow]:
        """Vectorized replacement for the per-(task, node) scalar loop."""
        for b in targets:
            self.benches.setdefault(b.name, b)
        queries = [PredictionQuery(t.task_name, tgt.name, t.input_gb)
                   for t in dag_tasks for tgt in targets]
        out = self.predict_batch(queries)
        method = getattr(self.predictor, "method_name", "service")
        return [PredictionRow(workflow=workflow, task=q.task, node=q.node,
                              input_gb=q.input_gb, predicted_s=float(m),
                              lower_s=float(lo), upper_s=float(hi),
                              method=method)
                for q, (m, lo, hi) in zip(queries, out)]
