"""OnlinePredictor: a fitted Lotaru predictor that keeps learning.

Lotaru (Section 4.5) fits once on downsampled local profiling traces and
never touches the model again — exactly the cold-start regime the paper
targets.  This wrapper folds in measurements *as tasks finish* (Hilman et
al.'s online-incremental insight) with two exact mechanisms:

  * per-task regression: the fitted BLR posterior is lifted into a
    conjugate NIG state (core.bayes.nig_from_blr); every completion is a
    rank-1 precision update — no refit, O(1) per event, exactly equal to
    the batch posterior on the same data;
  * per-node factor recalibration: observed/predicted log-ratios per node
    form a shrunk multiplicative correction on the Section 4.6 factors
    (the dominant heterogeneous error source: benchmark readings are noisy
    and workload-dependent).

Median-fallback (weakly correlated) tasks keep a streaming observation
buffer: the median/MAD update on full-scale observations fixes the
paper's known weakness of predicting merge-task runtimes from downsampled
profiles, and a task is promoted to a regression model if correlation
emerges once real input sizes spread out.
"""
from __future__ import annotations

import math
import threading
from collections import deque
from dataclasses import dataclass, field
from typing import (Deque, Dict, List, Mapping, Optional, Sequence, Tuple)

import numpy as np

from repro.core import bayes
from repro.core.correlation import STRONG_CORRELATION
from repro.core.extrapolation import MachineBench
from repro.core.predictor import LotaruPredictor
from repro.online.events import TaskCompletion, resolve_bench

MAX_BUFFER = 256          # per-task observation cap (bounded memory)
FACTOR_SHRINK_K = 2.0     # pseudo-count pulling the node correction to 1
FACTOR_CLIP = 4.0         # correction bounded to [1/4, 4]
FACTOR_DEADBAND = 0.12    # |median log ratio| below this -> no correction:
                          # deviations inside the static predictor's own
                          # error floor (Eq. 4's fixed CPU/IO weighting is
                          # ~10% off per task class) are task-mix bias, not
                          # a benchmark miss, and would not transfer to the
                          # other tasks scheduled on the node
NODE_MATURE_N = 5         # remote obs feed the task posterior only once the
                          # node's correction rests on this many ratios


MAX_NODE_LOGS = 64


@dataclass
class _NodeStats:
    """Observed/predicted log-ratios on one node, grouped by task.

    A node-level correction must capture what is common to ALL tasks on the
    node (a mis-benchmarked machine) and reject what is task-specific
    (Eq. 4's fixed CPU/IO weighting vs each task's real compute share).
    Scheduling phases serve the same task many times in a row, so pooled
    ratios would be dominated by whichever task ran last — instead each
    task contributes ONE median ratio, and the correction is the median
    across tasks, applied only when it is significant against the
    cross-task spread."""
    logs_by_task: Dict[str, List[float]] = field(default_factory=dict)

    @property
    def n(self) -> int:
        return sum(len(v) for v in self.logs_by_task.values())

    def update(self, task: str, ratio: float):
        logs = self.logs_by_task.setdefault(task, [])
        if len(logs) >= MAX_NODE_LOGS:
            logs.pop(0)
        logs.append(math.log(max(ratio, 1e-6)))

    @property
    def correction(self) -> float:
        meds = [float(np.median(v)) for v in self.logs_by_task.values() if v]
        if len(meds) < 2:
            return 1.0
        med = float(np.median(meds))
        a = np.asarray(meds)
        sd = 1.4826 * float(np.median(np.abs(a - med)))
        se_med = 1.2533 * sd / math.sqrt(len(meds))
        if abs(med) < max(FACTOR_DEADBAND, 2.0 * se_med):
            return 1.0
        w = self.n / (self.n + FACTOR_SHRINK_K)
        return float(np.clip(math.exp(w * med), 1.0 / FACTOR_CLIP,
                             FACTOR_CLIP))


@dataclass
class IngestStats:
    """Write-path telemetry, the ingest sibling of the decision plane's
    PlaneStats: how observations entered the posteriors, and at what
    batching leverage.  Predictor-level counters here; the serving shard
    aggregates them across bindings and adds its own drain/flush/
    generation counters for the `health` RPC."""
    batches: int = 0               # observe_many calls (or shard drains)
    records: int = 0               # completions ingested (incl. dropped)
    folded: int = 0                # records absorbed by the vectorized fold
    fold_dispatches: int = 0       # nig_update_batch dispatches issued
    scalar: int = 0                # records that took the per-record path
    lock_acquisitions: int = 0     # state-lock acquisitions for ingest
    flushes: int = 0               # oplog commits (group commit: 1/batch)
    generations_published: int = 0  # store COW generations from ingest

    def as_dict(self) -> dict:
        return {"batches": self.batches, "records": self.records,
                "folded": self.folded,
                "fold_dispatches": self.fold_dispatches,
                "scalar": self.scalar,
                "lock_acquisitions": self.lock_acquisitions,
                "flushes": self.flushes,
                "generations_published": self.generations_published}

    def merge(self, other: "IngestStats") -> "IngestStats":
        for f in ("batches", "records", "folded", "fold_dispatches",
                  "scalar", "lock_acquisitions", "flushes",
                  "generations_published"):
            setattr(self, f, getattr(self, f) + getattr(other, f))
        return self


def _ring() -> Deque[float]:
    return deque(maxlen=MAX_BUFFER)


@dataclass
class _TaskState:
    nig: Optional[dict]                     # streaming posterior (correlated)
    median_s: float
    spread_s: float
    xs: Deque[float] = field(default_factory=_ring)   # local-equivalent obs
    ys: Deque[float] = field(default_factory=_ring)   # (ring: newest 256)
    fit_xs: List[float] = field(default_factory=list)   # fit-time profiling
    fit_ys: List[float] = field(default_factory=list)   # points (refresh)
    since_refresh: int = 0    # posterior-moving completions since the last
                              # evidence refresh (RefreshPolicy.every_n)


class OnlinePredictor:
    """Same predict() interface as LotaruPredictor, plus observe()."""

    def __init__(self, base: LotaruPredictor,
                 benches: Optional[Mapping[str, MachineBench]] = None,
                 threshold: float = STRONG_CORRELATION):
        self.base = base
        self.benches = dict(benches or {})
        self.threshold = threshold
        self.version = 0                      # bumped on observe (store
        self.node_stats: Dict[str, _NodeStats] = {}     # sync trigger)
        self.tasks: Dict[str, _TaskState] = {}
        self._service = None                  # lazy predict_rows service
        for task, m in base.models.items():
            nig = bayes.nig_from_blr(m.posterior) if (
                m.correlated and m.posterior is not None) else None
            st = _TaskState(nig=nig, median_s=m.median_s,
                            spread_s=m.spread_s)
            if nig is not None and getattr(m, "fit_x", None) is not None:
                # fit-time points feed periodic evidence refreshes; a
                # median-fallback task keeps none (its downsampled profile
                # points are exactly what a later promotion must NOT trust)
                st.fit_xs = [float(v) for v in m.fit_x]
                st.fit_ys = [float(v) for v in m.fit_y]
            self.tasks[task] = st
        # non-destructive change feed: per-task last-change sequence numbers
        # (store bindings each diff against their own cursor, so ONE
        # predictor can feed any number of bindings/stores)
        self._change_seq = 1
        self._task_changes: Dict[str, int] = {t: 1 for t in self.tasks}
        # serializes state mutation (observe / apply_refresh / load_state)
        # against the maintenance plane's snapshot-fit-apply cycle; the
        # seq guard in apply_refresh is only airtight if the check and the
        # swap cannot interleave with a concurrent observe()
        self._state_lock = threading.Lock()
        self.ingest = IngestStats()           # write-path telemetry

    # ---- prediction ---------------------------------------------------------
    @property
    def method_name(self) -> str:
        return f"online-{self.base.method_name}"

    def task_names(self):
        return list(self.tasks)

    def changed_since(self, cursor: float):
        """-> (tasks whose posterior changed after `cursor`, new cursor).
        Non-destructive: each PosteriorStore binding keeps its own cursor
        and re-syncs only these rows instead of restacking every task on
        each version bump.  A binding that fails to write simply keeps its
        old cursor, so the rows stay due.  Covers load_state() rollbacks
        too (loading bumps every task's change sequence)."""
        seq = self._change_seq
        if cursor >= seq:
            return [], seq
        return (sorted(t for t, s in self._task_changes.items()
                       if s > cursor), seq)

    def _mark_changed(self, task: str) -> None:
        self._change_seq += 1
        self._task_changes[task] = self._change_seq

    def export_posterior(self, task: str) -> dict:
        """predict_blr-compatible posterior (feeds the batched service)."""
        st = self.tasks[task]
        if st.nig is not None:
            return bayes.nig_to_blr(st.nig)
        return bayes.constant_posterior(st.median_s, st.spread_s)

    def factor(self, task: str, target: Optional[MachineBench]) -> float:
        """static Section 4.6 factor x streaming per-node correction."""
        if target is None:
            return 1.0
        return self.base.factor(task, target) \
            * self.node_correction(target.name)

    def node_correction(self, node: Optional[str]) -> float:
        """streaming multiplicative correction for one node (1.0 while the
        observed/predicted ratios stay inside the significance gate)."""
        bench = self._bench(node)
        if bench is None:
            return 1.0
        stats = self.node_stats.get(bench.name)
        return stats.correction if stats else 1.0

    def predict(self, task: str, input_gb: float,
                target: Optional[MachineBench] = None,
                z: float = 1.96) -> Tuple[float, float, float]:
        """-> (mean, lower, upper) seconds on the target node."""
        mean, std = bayes.predict_blr_np(self.export_posterior(task),
                                         input_gb)
        f = self.factor(task, target)
        mean = max(float(mean), 1e-3) * f
        std = float(std) * f
        return mean, max(mean - z * std, 0.0), mean + z * std

    def predict_rows(self, dag_tasks, targets, workflow: str):
        from repro.online.service import PredictionService
        if self._service is None:
            self._service = PredictionService(self)
        return self._service.predict_rows(dag_tasks, targets, workflow)

    # ---- learning -----------------------------------------------------------
    def _bench(self, node: Optional[str]) -> Optional[MachineBench]:
        return resolve_bench(self.benches, node)

    def observe(self, comp: TaskCompletion) -> None:
        """Fold one completed task into the posteriors (exact updates).

        When `observe_log` is set (the serving shard's oplog hook) it is
        called with `comp` under the state lock BEFORE the update is
        applied — write-ahead order: a completion is durable in the log
        before it can mutate state, so replay-after-crash can never miss
        an applied observation, only re-apply a logged one that did not
        land (and replay from the checkpoint watermark is idempotent)."""
        with self._state_lock:
            self.ingest.lock_acquisitions += 1
            self.ingest.records += 1
            self.ingest.scalar += 1
            hook = getattr(self, "observe_log", None)
            if hook is not None:
                hook(comp)
            self._observe(comp)

    def observe_many(self, comps: Sequence[TaskCompletion]) -> int:
        """Fold a batch of completions under ONE state-lock acquisition.

        Exactness contract: the resulting state (and therefore
        `serve.state_digest`) is bit-identical to calling `observe(comp)`
        for each completion in order — the scalar chain is the oracle.
        The batch is regrouped per task; a task whose records are all
        local regression updates rides ONE `nig_update_batch` float64 fold
        dispatch (with grouped ring-buffer appends and a single shared
        change-feed publication for the whole fold group), while records
        that touch order-sensitive side state — remote completions feeding
        node-factor recalibration, median-fallback/promotion tasks,
        unknown tasks — replay through the exact per-record path in
        original arrival order.  The fold is safe to reorder against them
        because a fold-eligible task's NIG state is, by construction,
        neither read nor written by any other record in the batch.

        Write-ahead order is preserved: `observe_log_many` (or the scalar
        `observe_log` per record) runs under the lock BEFORE any state
        moves, so the group commit is durable before it can mutate state.
        Returns the number of records that advanced the predictor version
        (posterior or node-correction state moved; exactly the version
        delta the scalar chain would produce).
        """
        comps = list(comps)
        if not comps:
            return 0
        with self._state_lock:
            self.ingest.lock_acquisitions += 1
            self.ingest.batches += 1
            self.ingest.records += len(comps)
            hook_many = getattr(self, "observe_log_many", None)
            if hook_many is not None:
                hook_many(comps)
            else:
                hook = getattr(self, "observe_log", None)
                if hook is not None:
                    for c in comps:
                        hook(c)
            return self._observe_many(comps)

    def _observe_many(self, comps: List[TaskCompletion]) -> int:
        local_name = getattr(self.base.local_bench, "name", "local")
        local_names = (None, "", "local", local_name)
        per_task: Dict[str, List[TaskCompletion]] = {}
        for c in comps:
            if c.task in self.tasks:
                per_task.setdefault(c.task, []).append(c)
        fold_tasks: List[str] = []
        scalar_tasks = set()
        for task, recs in per_task.items():
            if self.tasks[task].nig is not None \
                    and all(c.node in local_names for c in recs):
                fold_tasks.append(task)
            else:
                scalar_tasks.add(task)

        applied = 0
        if fold_tasks:
            new_nigs = bayes.nig_update_batch(
                [self.tasks[t].nig for t in fold_tasks],
                [[c.input_gb for c in per_task[t]] for t in fold_tasks],
                [[c.runtime_s for c in per_task[t]] for t in fold_tasks])
            self._change_seq += 1           # ONE publication for the fold
            seq = self._change_seq
            for task, nig in zip(fold_tasks, new_nigs):
                st = self.tasks[task]
                st.nig = nig
                for c in per_task[task]:    # grouped ring-buffer appends
                    self._buffer(st, c.input_gb, c.runtime_s)
                st.since_refresh += len(per_task[task])
                self._task_changes[task] = seq
                applied += len(per_task[task])
            self.version += applied         # same per-record bump as the
            self.ingest.folded += applied   # scalar chain (digest parity)
            self.ingest.fold_dispatches += 1

        if scalar_tasks:
            v0 = self.version
            for c in comps:                 # original arrival order: node
                if c.task in scalar_tasks:  # stats are order-sensitive
                    self._observe(c)
                    self.ingest.scalar += 1
            applied += self.version - v0
        return applied

    def _observe(self, comp: TaskCompletion) -> None:
        if comp.task not in self.tasks:
            return
        st = self.tasks[comp.task]
        local_name = getattr(self.base.local_bench, "name", "local")
        if comp.node in (None, "", "local", local_name):
            bench, is_remote = None, False
        else:
            bench = self._bench(comp.node)
            if bench is None:
                # unknown node: the runtime cannot be attributed to either
                # the task model or a node factor — drop, never treat a
                # remote runtime as a local observation
                return
            is_remote = bench.name != local_name

        # 1) per-node factor recalibration from the observed/predicted ratio
        #    against the *static* factor (so the correction converges to the
        #    true capability ratio rather than chasing its own tail)
        stats = None
        if is_remote:
            local_mean, _ = bayes.predict_blr_np(
                self.export_posterior(comp.task), comp.input_gb)
            static = max(float(local_mean), 1e-3) * self.base.factor(
                comp.task, bench)
            stats = self.node_stats.setdefault(bench.name, _NodeStats())
            stats.update(comp.task, comp.runtime_s / max(static, 1e-6))

        # 2) per-task posterior update in local-equivalent units.  A remote
        #    observation mixes two error sources — the task model and the
        #    node factor (which is task-dependent: Eq. 4's fixed CPU/IO
        #    weighting vs the task's real compute share).  Regression
        #    posteriors only ingest local observations (unbiased for the
        #    task model); median-fallback tasks also ingest mature-node
        #    remote observations, where the 10x scale error of predicting a
        #    merge task from downsampled profiles dwarfs any factor bias.
        if st.nig is not None:
            if is_remote:
                self.version += 1    # node correction moved, posterior not:
                return               # no dirty row, no store COW write
            st.nig = bayes.nig_update(st.nig, comp.input_gb, comp.runtime_s)
            self._buffer(st, comp.input_gb, comp.runtime_s)
            st.since_refresh += 1
        else:
            if is_remote and (stats is None or stats.n < NODE_MATURE_N):
                self.version += 1
                return
            f = self.factor(comp.task, bench)
            self._buffer(st, comp.input_gb, comp.runtime_s / max(f, 1e-6))
            self._update_median(st)
            self._maybe_promote(comp.task, st)
        self._mark_changed(comp.task)   # posterior moved -> row resync due
        self.version += 1

    @staticmethod
    def _buffer(st: _TaskState, x: float, y: float) -> None:
        # ring (deque maxlen): keep the NEWEST window.  The buffer feeds
        # median updates, promotion checks, and periodic evidence
        # refreshes — all of which should weight recent production-scale
        # behaviour, not whichever observations happened to arrive first
        st.xs.append(float(x))
        st.ys.append(float(y))

    def _update_median(self, st: _TaskState) -> None:
        if st.ys:
            y = np.asarray(st.ys, np.float64)
            st.median_s = float(np.median(y))
            # floor the spread at 5% of the median: a single (or perfectly
            # consistent) observation has MAD 0, and a ~0 spread would make
            # every interval degenerate and the rescheduler's drift band
            # fire on microsecond median shifts
            mad = 1.4826 * float(np.median(np.abs(y - np.median(y))))
            st.spread_s = max(mad, 0.05 * abs(st.median_s), 1e-3)

    def _maybe_promote(self, task: str, st: _TaskState) -> None:
        """weak-correlation verdicts from tiny downsampled profiles can be
        wrong at production input scales: refit + lift once the streamed
        observations show strong correlation."""
        if len(st.xs) < 4:
            return
        x = np.asarray(st.xs, np.float64)
        y = np.asarray(st.ys, np.float64)
        if np.std(x) < 1e-12 or np.std(y) < 1e-12:
            return
        r = float(np.corrcoef(x, y)[0, 1])
        if abs(r) >= self.threshold:
            st.nig = bayes.nig_from_blr(bayes.refresh_fit([], [], x, y))
            st.since_refresh = 0       # the promotion fit IS a fresh fit

    def prediction_std(self, task: str, input_gb: float) -> float:
        """local predictive std (the uncertainty band rescheduling uses)."""
        _, std = bayes.predict_blr_np(self.export_posterior(task), input_gb)
        return float(std)

    # ---- periodic evidence refresh (online.maintenance protocol) ------------
    def refresh_due(self, policy) -> List[str]:
        """Tasks whose streaming posterior is due for an evidence refresh
        under `policy` (online.maintenance.RefreshPolicy): enough
        completions since the last refresh, or the streaming noise estimate
        b/a drifted beyond `drift_ratio` x the lift-time level.  Only
        regression tasks with at least one streamed observation qualify —
        median-fallback states re-estimate on every completion already."""
        due = []
        for task, st in self.tasks.items():
            if st.nig is None or st.nig["n_obs"] <= 0:
                continue
            if len(st.fit_xs) + len(st.xs) < policy.min_points:
                continue
            if st.since_refresh >= policy.every_n:
                due.append(task)
                continue
            if policy.drift_ratio is not None and st.since_refresh > 0:
                s2_lift = float(st.nig.get("s2_lift", 0.0))
                if s2_lift > 0.0:
                    ratio = (st.nig["b"] / st.nig["a"]) / s2_lift
                    if not (1.0 / policy.drift_ratio < ratio
                            < policy.drift_ratio):
                        due.append(task)
        return due

    def refresh_snapshot(self, tasks) -> Dict[str, Tuple[int, np.ndarray,
                                                         np.ndarray]]:
        """-> task -> (change seq, x, y): the full evidence for a refresh
        fit — fit-time profiling points plus the streamed ring buffer
        (streamed-only observations are preserved, never discarded).  The
        change seq lets `apply_refresh` reject a fit that raced with a
        concurrent observe() instead of silently clobbering it."""
        out = {}
        with self._state_lock:
            for t in tasks:
                st = self.tasks[t]
                out[t] = (self._task_changes.get(t, 0),
                          np.asarray(st.fit_xs + list(st.xs), np.float64),
                          np.asarray(st.fit_ys + list(st.ys), np.float64))
        return out

    def change_seq(self, task: str) -> int:
        """Current change-feed sequence of one task — the maintenance
        plane captures it at publish time so a binding cursor is only
        advanced past rows nothing has touched since."""
        return self._task_changes.get(task, 0)

    def apply_refresh(self, task: str, post: Mapping, seq=None) -> bool:
        """Moment-match a refreshed BLR posterior (the batched evidence
        fixed point over this task's refresh_snapshot data) back into the
        streaming NIG state.  Returns False — leaving the task due — when
        `seq` shows an observation landed after the snapshot was taken
        (checked and swapped under the state lock, so the verdict cannot
        race a concurrent observe)."""
        with self._state_lock:
            st = self.tasks[task]
            if seq is not None and self._task_changes.get(task) != seq:
                return False
            st.nig = bayes.nig_from_blr(post)
            st.since_refresh = 0
            self._mark_changed(task)
            self.version += 1
            return True

    # ---- checkpoint (PosteriorStore save/resume) ----------------------------
    def export_state(self) -> dict:
        """JSON-serializable streaming state: NIG posteriors, median/MAD
        states with their observation buffers, per-node correction logs.
        Pure-python floats/lists only — json float repr round-trips float64
        exactly, so save -> load_state is bit-identical.  Taken under the
        state lock: a checkpoint racing a concurrent observe/apply_refresh
        must capture a consistent instant, never a torn one (e.g. a
        nig_update without its matching buffer append)."""
        with self._state_lock:
            return self._export_state()

    def _export_state(self) -> dict:
        def _leaf(v):
            return v.tolist() if isinstance(v, np.ndarray) else float(v)
        tasks = {}
        for name, st in self.tasks.items():
            tasks[name] = {
                "nig": ({k: _leaf(v) for k, v in st.nig.items()}
                        if st.nig is not None else None),
                "median_s": float(st.median_s),
                "spread_s": float(st.spread_s),
                "xs": [float(v) for v in st.xs],
                "ys": [float(v) for v in st.ys],
                "fit_xs": [float(v) for v in st.fit_xs],
                "fit_ys": [float(v) for v in st.fit_ys],
                "since_refresh": int(st.since_refresh)}
        nodes = {name: {t: [float(v) for v in logs]
                        for t, logs in s.logs_by_task.items()}
                 for name, s in self.node_stats.items()}
        return {"version": int(self.version), "threshold": float(self.threshold),
                "tasks": tasks, "nodes": nodes}

    def load_state(self, state: dict) -> None:
        """Inverse of export_state: overwrite ALL streaming state so a
        restarted predictor resumes exactly where the checkpoint left off
        (the fitted base model is reconstructed by the caller; everything
        learned since fit time comes from here)."""
        with self._state_lock:
            self._load_state(state)

    def _load_state(self, state: dict) -> None:
        self.version = int(state["version"])
        self.threshold = float(state["threshold"])
        self.tasks = {}
        for name, ts in state["tasks"].items():
            nig = ts["nig"]
            if nig is not None:
                nig = {k: (np.asarray(v, np.float64) if isinstance(v, list)
                           else float(v)) for k, v in nig.items()}
            self.tasks[name] = _TaskState(
                nig=nig, median_s=float(ts["median_s"]),
                spread_s=float(ts["spread_s"]),
                xs=deque((float(v) for v in ts["xs"]), maxlen=MAX_BUFFER),
                ys=deque((float(v) for v in ts["ys"]), maxlen=MAX_BUFFER),
                fit_xs=[float(v) for v in ts.get("fit_xs", [])],
                fit_ys=[float(v) for v in ts.get("fit_ys", [])],
                since_refresh=int(ts.get("since_refresh", 0)))
        self.node_stats = {}
        for node, by_task in state["nodes"].items():
            s = _NodeStats()
            s.logs_by_task = {t: [float(v) for v in logs]
                              for t, logs in by_task.items()}
            self.node_stats[node] = s
        self._change_seq += 1        # every row is due for resync, on every
        self._task_changes = {t: self._change_seq for t in self.tasks}
        # binding's cursor (version may equal what a binding already synced)
