"""Event vocabulary of the online subsystem (leaf module: no repro deps
beyond dataclasses, so the simulator and service can both speak it)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class TaskCompletion:
    """One finished task execution, as observed by the resource manager."""
    workflow: str
    uid: str                  # physical DAG vertex (e.g. 'bwa_mem__s3')
    task: str                 # abstract task name (e.g. 'bwa_mem')
    node: str                 # node the task ran on
    input_gb: float
    runtime_s: float
    finish_time: float = 0.0


@dataclass(frozen=True)
class PredictionQuery:
    """One (task, node, input) runtime request against the service."""
    task: str
    node: Optional[str]       # None -> local machine (factor 1)
    input_gb: float


def resolve_bench(benches, node: Optional[str]):
    """Benchmark lookup shared by predictor and service: exact name first,
    then the cluster-instance convention 'N2-3' -> 'N2'.  None when the
    node is unknown (callers decide whether that is an error or a drop)."""
    if node is None:
        return None
    b = benches.get(node)
    if b is None and "-" in node:
        b = benches.get(node.rsplit("-", 1)[0])
    return b
