"""Event vocabulary of the online subsystem (near-leaf module: depends
only on `repro.store.keys`, so the simulator and service can both speak
it)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.store.keys import resolve_bench  # noqa: F401  (compat re-export)


@dataclass(frozen=True)
class TaskCompletion:
    """One finished task execution, as observed by the resource manager."""
    workflow: str
    uid: str                  # physical DAG vertex (e.g. 'bwa_mem__s3')
    task: str                 # abstract task name (e.g. 'bwa_mem')
    node: str                 # node the task ran on
    input_gb: float
    runtime_s: float
    finish_time: float = 0.0


@dataclass(frozen=True)
class PredictionQuery:
    """One (task, node, input) runtime request against the service."""
    task: str
    node: Optional[str]       # None -> local machine (factor 1)
    input_gb: float
