"""Online prediction subsystem: streaming Bayesian updates from task
completions, a batched prediction service, and in-flight HEFT rescheduling.

Layering: `events` is leaf-level (shared vocabulary), `predictor` wraps a
fitted LotaruPredictor with exact conjugate updates, `service` is a
(tenant, workflow) view over the shared `repro.store.PosteriorStore`
(stacked rows, copy-on-write snapshots, checkpointing) dispatching the
fused posterior-predictive kernel, `maintenance` is the posterior
maintenance plane (fleet-wide periodic evidence refresh in one batched fit
dispatch), `rescheduler` drives `workflow.simulator.execute_adaptive`.
Multi-tenant coalescing lives in `repro.store.frontend`.
"""
from repro.online.events import TaskCompletion, PredictionQuery  # noqa: F401
from repro.online.predictor import (IngestStats,                 # noqa: F401
                                    OnlinePredictor)
from repro.online.service import PredictionService               # noqa: F401
from repro.online.maintenance import (FleetRefresher,            # noqa: F401
                                      RefreshPolicy, RefreshReport)
from repro.online.rescheduler import OnlineReschedulingPlanner   # noqa: F401
