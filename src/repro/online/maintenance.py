"""Posterior maintenance plane: fleet-wide periodic evidence refresh.

Streaming NIG updates (online.predictor) are *exact conjugate* updates —
given the (alpha, beta) hyperparameters the MacKay evidence fixed point
chose at fit time.  After hundreds of online completions that lift no
longer reflects the data: the standardization is frozen at profile scale
and the prior precision was tuned for 3-10 downsampled points, which
degrades exactly the uncertainty estimates the scheduler consumes.  The
standard remedy (Hilman et al. 2018) is periodic re-fitting from the
accumulated observations.

This module closes that loop across the whole store:

  * `RefreshPolicy` decides *when* a task is due — every N posterior-moving
    completions, and/or when the streaming noise estimate b/a drifts beyond
    `drift_ratio` x the lift-time level;
  * `FleetRefresher` gathers the ragged observation buffers of every due
    task across every tenant bound to one `PosteriorStore`, re-runs the
    evidence fixed point for all of them in ONE padded/masked batched fit
    dispatch (`store.compute.fit_stacked`: Pallas kernel on TPU, jit'd vmap
    elsewhere), moment-matches the refreshed posteriors back into the
    streaming NIG states (`OnlinePredictor.apply_refresh`), and publishes
    every rewritten row through the store in a single copy-on-write
    generation bump.

The refresh is out-of-band by construction: the expensive fit runs with no
locks held (a fit that races a concurrent observe() is rejected per task by
its change seq and the task simply stays due), and readers keep serving
from immutable snapshots until the one-generation publish lands — in-flight
predict batches are never blocked.  `start()` runs the loop on a daemon
thread; `repro.store.frontend.AsyncPredictionFrontend` can own the same
loop next to its batch-window worker.
"""
from __future__ import annotations

import contextlib
import threading
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.store.posterior import PosteriorStore, TenantBinding


@dataclass
class RefreshPolicy:
    """When is a task's streaming posterior due for an evidence refresh?

    every_n: posterior-moving completions since the last refresh (the
        Hilman-style periodic trigger).
    drift_ratio: optional evidence-drift trigger — refresh as soon as the
        streaming noise estimate b/a leaves
        (s2_lift / drift_ratio, s2_lift * drift_ratio), i.e. the data
        contradicts the lift-time noise level before the periodic counter
        fires.
    min_points: never refit on fewer total (fit + streamed) points.

    The last two are multi-tenant fairness budgets, enforced by
    `FleetRefresher.due()` (so every entry point — refresh, maybe_refresh,
    the daemon loop — sees the same throttled view):

    max_tasks_per_tenant_per_cycle: cap on how many of one tenant's due
        tasks enter a single refresh pass.  A noisy tenant streaming
        completions into hundreds of tasks fills its quota and the rest
        stay due for the next cycle — they are deferred, never dropped —
        while other tenants' tasks still make the dispatch.
    min_interval_s: per-task refresh rate limit — a task refreshed less
        than this many seconds ago is not due yet, no matter how many
        completions landed (protects the fit dispatch from a tenant whose
        every_n fires continuously).
    """
    every_n: int = 32
    drift_ratio: Optional[float] = None
    min_points: int = 4
    max_tasks_per_tenant_per_cycle: Optional[int] = None
    min_interval_s: Optional[float] = None


@dataclass
class RefreshReport:
    """What one `FleetRefresher.refresh()` pass did."""
    n_tasks: int = 0          # posteriors refreshed and published
    n_tenants: int = 0        # distinct tenants those rows belong to
    n_dispatches: int = 0     # batched fit dispatches issued (0 or 1)
    n_stale: int = 0          # fits rejected by a racing observe()
    generation: int = -1      # store generation after the publish
    duration_s: float = 0.0


class FleetRefresher:
    """Batched evidence refresh for every namespace bound to one store.

    One instance owns the refresh schedule of a whole (multi-tenant)
    `PosteriorStore`; `refresh()` is safe to call from any thread, and
    `start(interval_s)` runs `maybe_refresh()` on a daemon thread.
    """

    def __init__(self, store: PosteriorStore,
                 policy: Optional[RefreshPolicy] = None, impl: str = "auto"):
        self.store = store
        self.policy = policy or RefreshPolicy()
        self.impl = impl
        self.dispatch_count = 0          # lifetime batched-fit dispatches
        self.reports: List[RefreshReport] = []
        self.failure_count = 0           # background passes that raised
        self.last_error: Optional[BaseException] = None   # most recent one
        self._last_refresh: Dict[Tuple[int, str], float] = {}   # applied-at
        self._stop = threading.Event()                          # monotonic
        self._thread: Optional[threading.Thread] = None

    # ---- due detection ------------------------------------------------------
    def due(self) -> List[Tuple[TenantBinding, str]]:
        """(binding, task) pairs due under the policy, across all tenants.
        Predictors without the refresh protocol (plain LotaruPredictor) are
        skipped — their posteriors are not streaming.

        The policy's fairness budgets apply here: tasks refreshed within
        `min_interval_s` are not yet due, and each tenant contributes at
        most `max_tasks_per_tenant_per_cycle` tasks per sweep (the rest
        remain due and surface on later sweeps — deferred, not dropped)."""
        out = []
        pol = self.policy
        now = time.monotonic()
        per_tenant: Dict[str, int] = {}
        for b in self.store.bindings():
            fn = getattr(b.predictor, "refresh_due", None)
            if fn is None:
                continue
            for t in fn(pol):
                if pol.min_interval_s is not None:
                    last = self._last_refresh.get((id(b.predictor), t))
                    if last is not None and now - last < pol.min_interval_s:
                        continue
                if pol.max_tasks_per_tenant_per_cycle is not None:
                    n = per_tenant.get(b.tenant, 0)
                    if n >= pol.max_tasks_per_tenant_per_cycle:
                        continue
                    per_tenant[b.tenant] = n + 1
                out.append((b, t))
        return out

    # ---- the batched refresh pass -------------------------------------------
    def refresh(self, due: Optional[List[Tuple[TenantBinding, str]]] = None
                ) -> RefreshReport:
        """Refresh every due task in ONE batched fit dispatch and publish
        all rewritten rows in ONE store generation.  See module docstring
        for the race/locking story."""
        from repro.kernels.bayes_fit import pad_ragged
        from repro.store.compute import fit_stacked
        t0 = time.perf_counter()
        if due is None:
            due = self.due()
        # one fit row per distinct (predictor, task): two bindings may feed
        # the same predictor into two namespaces — fit once, publish to both.
        # Buffers are snapshotted in ONE refresh_snapshot call per predictor
        # (one state-lock acquisition, one consistent instant), not per task.
        rows: Dict[Tuple[int, str], dict] = {}
        by_predictor: Dict[int, Tuple[object, List[str]]] = {}
        for b, task in due:
            p = b.predictor
            key = (id(p), task)
            if key not in rows:
                rows[key] = {"p": p, "task": task, "bindings": []}
                by_predictor.setdefault(id(p), (p, []))[1].append(task)
            if b not in rows[key]["bindings"]:
                rows[key]["bindings"].append(b)
        for p, tasks in by_predictor.values():
            for task, (seq, x, y) in p.refresh_snapshot(tasks).items():
                rows[(id(p), task)].update(seq=seq, x=x, y=y)
        if not rows:
            report = RefreshReport(generation=self.store.generation,
                                   duration_s=time.perf_counter() - t0)
            self._record(report)
            return report

        # ONE padded/masked evidence fixed-point dispatch for the fleet
        keys = list(rows)
        x, y, m = pad_ragged([rows[k]["x"] for k in keys],
                             [rows[k]["y"] for k in keys])
        post = fit_stacked(x, y, m, impl=self.impl)
        self.dispatch_count += 1

        # moment-match back into the streaming states; a task whose change
        # seq moved while the fit ran keeps its (newer) state and stays due
        applied: List[dict] = []
        n_stale = 0
        for i, k in enumerate(keys):
            r = rows[k]
            row_post = {leaf: v[i] for leaf, v in post.items()}
            if r["p"].apply_refresh(r["task"], row_post, seq=r["seq"]):
                applied.append(r)
                self._last_refresh[k] = time.monotonic()   # min_interval_s
            else:                                          # rate-limit stamp
                n_stale += 1

        # publish: one put_many -> one COW generation across all tenants,
        # then advance each binding's cursor past the rows just written.
        # Binding locks are taken in namespace order (always before the
        # store lock inside put_many — the same order sync() uses), so a
        # concurrent sync/flush serializes cleanly instead of deadlocking.
        bindings = sorted({id(b): b for r in applied for b in r["bindings"]
                           }.values(), key=lambda b: b.namespace)
        tenants = set()
        n_rows = 0
        with contextlib.ExitStack() as stack:
            for b in bindings:
                stack.enter_context(b._sync_lock)
            items = []
            per_binding: Dict[int, Dict[str, int]] = {}
            for r in applied:
                # seq captured BEFORE the export: if an observe lands in
                # between, the exported row is fresher than the seq and the
                # cursor advance below refuses — the row just stays due
                seq = r["p"].change_seq(r["task"])
                for b in r["bindings"]:
                    if b._detached:      # evicted/displaced mid-refresh:
                        continue         # never write its rows back
                    items.append((b.key(r["task"]),
                                  r["p"].export_posterior(r["task"])))
                    per_binding.setdefault(id(b), {})[r["task"]] = seq
                    tenants.add(b.tenant)
            if items:
                self.store.put_many(items)
                n_rows = len({str(k) for k, _ in items})
            for b in bindings:
                if not b._detached:
                    b._advance_cursor(per_binding.get(id(b), {}))

        report = RefreshReport(n_tasks=n_rows, n_tenants=len(tenants),
                               n_dispatches=1, n_stale=n_stale,
                               generation=self.store.generation,
                               duration_s=time.perf_counter() - t0)
        self._record(report)
        return report

    def _record(self, report: RefreshReport) -> None:
        if len(self.reports) >= 4096:    # telemetry, not a log: a daemon
            del self.reports[:2048]      # loop must not grow without bound
        self.reports.append(report)

    def maybe_refresh(self) -> Optional[RefreshReport]:
        """refresh() only if anything is due (the polling entry point —
        a no-op pass costs one due() sweep and no dispatch)."""
        due = self.due()
        return self.refresh(due) if due else None

    # ---- background loop ----------------------------------------------------
    def start(self, interval_s: float = 1.0) -> "FleetRefresher":
        """Run maybe_refresh() every `interval_s` on a daemon thread."""
        if self._thread is not None:
            raise RuntimeError("refresher already running")
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, args=(interval_s,),
                                        daemon=True,
                                        name="posterior-refresher")
        self._thread.start()
        return self

    def _loop(self, interval_s: float) -> None:
        while not self._stop.wait(interval_s):
            try:
                self.maybe_refresh()
            except Exception as e:       # noqa: BLE001  (a refresh bug must
                # not kill the maintenance loop — but it must not die
                # silently either: operators watch failure_count/last_error
                # (a plane whose reports stop moving while these climb is
                # persistently failing, not idle)
                self.failure_count += 1
                self.last_error = e

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=10.0)
        self._thread = None

    def __enter__(self) -> "FleetRefresher":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()
